"""Sim-vs-real transport calibration: same plans, both backends.

The communication-practicality surveys (PAPERS.md: Le et al.; Shahid et
al.) warn that simulated FL traffic routinely diverges from deployed
traffic. The pluggable transport seam (``runtime/transport_base.py``)
makes that divergence measurable: this benchmark executes the *same*
per-round MessagePlan of every registered aggregation technique on the
discrete-event simulator and on real asyncio loopback TCP sockets, then
compares the two transcripts.

Contract (asserted): the no-loss transcripts are **byte-exact** — same
``total_bytes``, same per-round split, same per-link split — for every
technique at every peer count, including a MAR+MKD plan (distillation
prefix rounds) and an int8-compressed wire ladder. Wall-clock is
**reported, not asserted**: the simulator's seconds come from modeled
links, the socket backend's from actual loopback transmission of real
int8-serialized tensors, and the ratio between them is the calibration
signal (EXPERIMENTS.md §Sim-vs-real calibration).

A second section runs the *multi-process* socket path: the same plans
over a two-rank address-book world (``run_multiprocess`` — real spawn
processes, fixed host:port endpoints, cross-rank TCP), with the merged
per-rank transcripts gated byte-exact against the simulator too. That
is the "beyond loopback" claim: the address-book deployment moves
exactly the bytes the model says it does.

Exit status is non-zero on any byte mismatch, so CI can gate on it.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, std_argparser
from repro.core import topology
from repro.core.aggregation import TECHNIQUES, build_pipeline
from repro.core.moshpit import plan_grid
from repro.runtime.socket_transport import (encode_state_payloads,
                                            run_multiprocess)
from repro.runtime.transport_base import build_transport

ORDER = ("fedavg", "hierarchical", "mar", "gossip", "rdfl", "ar")


def _transcripts(mplan, n, seed, payloads=None):
    sim = build_transport("sim", n, profile="uniform", seed=seed)
    sock = build_transport("socket", n, seed=seed)
    return sim.run(mplan), sock.run(mplan, payloads=payloads)


def main(argv=None) -> int:
    ap = std_argparser(__doc__)
    ap.add_argument("--model-kb", type=float, default=64.0,
                    help="state bytes per transfer, in KB")
    args = ap.parse_args(argv)

    peer_counts = (4,) if args.smoke else (4, 8)
    if args.full:
        peer_counts = (4, 8, 16)
    model_bytes = int(args.model_kb * 1000)

    techniques = [t for t in ORDER if t in TECHNIQUES] + \
        sorted(set(TECHNIQUES) - set(ORDER))
    failures = 0
    for n in peer_counts:
        plan = plan_grid(n)
        mask = np.ones(n, np.float32)
        # real tensors on the wire: a synthetic peer-stacked update,
        # int8-serialized exactly like the federation's socket path
        rng = np.random.default_rng(args.seed)
        payloads = encode_state_payloads(
            {"w": rng.normal(size=(n, 256, 16)).astype(np.float32)})
        for tech in techniques:
            pipe = build_pipeline(tech, plan)
            mplan = pipe.message_plan(mask, model_bytes, n)
            tr_sim, tr_sock = _transcripts(mplan, n, args.seed, payloads)
            exact = (tr_sock.total_bytes == tr_sim.total_bytes
                     and tr_sock.bytes_by_round == tr_sim.bytes_by_round
                     and tr_sock.bytes_by_link == tr_sim.bytes_by_link)
            failures += not exact
            emit("transport_calibration", technique=tech, n_peers=n,
                 messages=mplan.n_messages,
                 bytes_sim=int(tr_sim.total_bytes),
                 bytes_socket=int(tr_sock.total_bytes),
                 byte_exact=exact,
                 payload_bytes=int(tr_sock.payload_bytes),
                 sim_s=round(tr_sim.iteration_s, 6),
                 wall_s=round(tr_sock.iteration_s, 6),
                 wall_over_sim=round(
                     tr_sock.iteration_s / max(tr_sim.iteration_s, 1e-12),
                     3))

        # MKD prefix rounds ride the same transports
        pipe = build_pipeline("mar", plan)
        mplan = pipe.message_plan(mask, model_bytes, n, use_kd=True,
                                  kd_logit_bytes=1024)
        tr_sim, tr_sock = _transcripts(mplan, n, args.seed, payloads)
        kd_exact = (tr_sock.total_bytes == tr_sim.total_bytes
                    and tr_sock.kd_bytes == tr_sim.kd_bytes)
        failures += not kd_exact
        emit("transport_calibration", technique="mar+kd", n_peers=n,
             messages=mplan.n_messages,
             bytes_sim=int(tr_sim.total_bytes),
             bytes_socket=int(tr_sock.total_bytes),
             kd_bytes=int(tr_sock.kd_bytes), byte_exact=kd_exact,
             sim_s=round(tr_sim.iteration_s, 6),
             wall_s=round(tr_sock.iteration_s, 6))

        # compressed wire sizes shrink both backends identically
        pipe = build_pipeline("mar", plan, compress="int8_ef")
        mplan = pipe.message_plan(mask, model_bytes, n)
        tr_sim, tr_sock = _transcripts(mplan, n, args.seed, payloads)
        c_exact = tr_sock.total_bytes == tr_sim.total_bytes
        failures += not c_exact
        emit("transport_calibration", technique="mar+int8_ef", n_peers=n,
             bytes_sim=int(tr_sim.total_bytes),
             bytes_socket=int(tr_sock.total_bytes), byte_exact=c_exact,
             analytic=int(topology.iteration_bytes(
                 "mar", n, model_bytes, plan) / 4),
             sim_s=round(tr_sim.iteration_s, 6),
             wall_s=round(tr_sock.iteration_s, 6))

    # beyond loopback: the same plans over a two-rank address-book
    # world (real spawned processes, fixed ports, cross-rank TCP);
    # merged per-rank transcripts must match the simulator byte-exact
    mp_techs = ("mar", "ar", "fedavg")
    for n in peer_counts:
        plan = plan_grid(n)
        mask = np.ones(n, np.float32)
        plans = [build_pipeline(t, plan).message_plan(mask, model_bytes,
                                                      n)
                 for t in mp_techs]
        merged = run_multiprocess(n, plans, world_size=2,
                                  seed=args.seed)
        sim = build_transport("sim", n, profile="uniform",
                              seed=args.seed)
        for tech, mplan, tr_mp in zip(mp_techs, plans, merged):
            tr_sim = sim.run(mplan)
            exact = (tr_mp.total_bytes == tr_sim.total_bytes
                     and tr_mp.bytes_by_round == tr_sim.bytes_by_round
                     and tr_mp.bytes_by_link == tr_sim.bytes_by_link)
            failures += not exact
            emit("transport_calibration", technique=tech + "+2proc",
                 n_peers=n, messages=mplan.n_messages,
                 bytes_sim=int(tr_sim.total_bytes),
                 bytes_socket=int(tr_mp.total_bytes), byte_exact=exact,
                 wall_s=round(tr_mp.iteration_s, 6))

    emit("transport_calibration", summary=True,
         peer_counts=str(peer_counts), byte_mismatches=failures)
    return 1 if failures else 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
