"""Format dry-run JSON records into the EXPERIMENTS.md roofline tables.

  PYTHONPATH=src python -m benchmarks.roofline_report dryrun.json
"""
from __future__ import annotations

import json
import sys


def fmt_bytes(b):
    if b >= 2 ** 30:
        return f"{b/2**30:.1f}G"
    if b >= 2 ** 20:
        return f"{b/2**20:.1f}M"
    return f"{b/2**10:.0f}K"


def table(records, mesh_filter=None):
    rows = []
    header = ("| arch | shape | mesh | peers | compute_s | memory_s | "
              "collective_s | dominant | useful | MFU | HBM/chip |")
    sep = "|" + "---|" * 11
    rows.append(header)
    rows.append(sep)
    for r in records:
        if r.get("status") == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | — |"
                        f" — | skipped (quadratic attn) | — | — | — |")
            continue
        if r.get("status") != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | "
                        f"{r.get('mesh','?')} | — | — | — | — | "
                        f"**FAILED** {r.get('error','')[:40]} | — | — | — |")
            continue
        if mesh_filter and mesh_filter not in r["mesh"]:
            continue
        ma = r.get("memory_per_chip", {})
        rows.append(
            f"| {r['arch']} | {r['shape']} | "
            f"{'multi' if 'multi' in r['mesh'] else 'single'} | "
            f"{r.get('n_peers','—')} | "
            f"{r['compute_s']:.4f} | {r['memory_s']:.3f} | "
            f"{r['collective_s']:.3f} | {r['dominant']} | "
            f"{r['useful_flops_fraction']*100:.0f}% | "
            f"{r['mfu']*100:.1f}% | "
            f"{ma.get('total_bytes', 0)/2**30:.1f}G |")
    return "\n".join(rows)


def main(argv=None) -> int:
    path = (argv or sys.argv[1:])[0]
    with open(path) as f:
        records = json.load(f)
    ok = [r for r in records if r.get("status") == "ok"]
    print(table(records))
    print()
    print(f"# {len(ok)} ok / "
          f"{sum(1 for r in records if r.get('status')=='skipped')} "
          f"skipped / "
          f"{sum(1 for r in records if r.get('status') not in ('ok','skipped'))}"
          f" failed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
