"""Shared benchmark helpers: CSV emission + compact run settings.

Benchmarks default to paper-faithful settings scaled to this CPU
container (fewer peers/iterations than the paper's 125x several hundred;
``--full`` restores paper scale). Every module prints
``name,key=value,...`` CSV rows so ``benchmarks/run.py`` can tee a
single machine-readable stream.
"""
from __future__ import annotations

import argparse
import os
import sys
import time
from typing import Dict, List


def emit(_row: str, **fields):
    parts = [_row] + [f"{k}={v}" for k, v in fields.items()]
    print(",".join(parts), flush=True)


def std_argparser(desc: str) -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=desc)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale settings (slow)")
    ap.add_argument("--smoke", action="store_true",
                    help="minimal settings (CI smoke jobs)")
    ap.add_argument("--seed", type=int, default=0)
    return ap


def scale(full: bool, smoke: bool = False) -> Dict[str, int]:
    """(peers, iterations, eval_every) per mode."""
    if smoke:
        return dict(peers=8, iters=6, eval_every=3, local_batches=1)
    if full:
        return dict(peers=125, iters=150, eval_every=5, local_batches=1)
    return dict(peers=27, iters=30, eval_every=5, local_batches=2)
