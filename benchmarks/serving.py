"""Offered-load serving sweep: continuous batching vs sequential loop.

The serving tier's acceptance benchmark (ISSUE 8): drain a mixed-length
session set through the paged-KV continuous-batching
:class:`~repro.serve.engine.DecodeServer` and through the sequential
one-session-at-a-time baseline (the pre-engine ``launch/serve.py``
loop), on identical prompts, weights and greedy decoding. Reports
tokens/s plus p50/p99 per-token latency and p50 time-to-first-token per
arm. Both arms are warmed first so jit compilation never lands in a
timed region.

A hot-swap cell re-runs the top offered-load point with an identity
``swap_params`` mid-drain and checks zero dropped sessions and an
unchanged total token count.

Gate: continuous batching must reach >= 2x the sequential tokens/s at
the highest offered load (the batch-parallel decode steps amortize the
per-step dispatch + weight-read cost that the sequential loop pays per
token). Emits CSV rows plus ``BENCH_serving.json``; exits nonzero on a
sub-gate sweep or a hot-swap drop.
"""
from __future__ import annotations

import dataclasses
import json
import sys
import time

import jax
import numpy as np

from benchmarks.common import emit, std_argparser
from repro.configs.registry import get_config, get_smoke_config
from repro.models.model import Model
from repro.serve import DecodeServer, ServeConfig, run_sequential

GATE_SPEEDUP = 2.0
ARCH = "starcoder2-3b"


def _lat(sessions):
    times = [t for s in sessions for t in s.token_times[1:]]
    ttft = [s.token_times[0] for s in sessions]
    return {
        "p50_ms": round(float(np.percentile(times, 50)) * 1e3, 3),
        "p99_ms": round(float(np.percentile(times, 99)) * 1e3, 3),
        "p50_ttft_ms": round(float(np.percentile(ttft, 50)) * 1e3, 3),
    }


def _mk_prompts(rng, n, pad_len, vocab):
    plens = rng.integers(max(1, pad_len // 4), pad_len + 1, n)
    return [rng.integers(0, vocab, p).tolist() for p in plens]


def run_cell(model, params, prompts, scfg: ServeConfig, swap_mid: bool
             ) -> dict:
    """One offered-load point: sequential arm then engine arm on the
    same prompts. The engine instance is pre-warmed on two throwaway
    sessions (drained to quiescence) before the timed drain."""
    gen, pad = scfg.max_new, scfg.pad_len
    # -- sequential baseline (warm one session, then time) -------------
    run_sequential(model, params, [prompts[0]], max_new=gen, pad_len=pad)
    t0 = time.perf_counter()
    seq_done = run_sequential(model, params, prompts, max_new=gen,
                              pad_len=pad)
    seq_s = time.perf_counter() - t0
    seq_toks = sum(len(s.generated) for s in seq_done)

    # -- continuous batching -------------------------------------------
    srv = DecodeServer(model, params, scfg)
    for p in prompts[:2]:
        srv.enqueue(p)
    srv.run()
    srv.assert_quiescent()
    srv.finished.clear()                        # warmup excluded
    for p in prompts:
        srv.enqueue(p)
    t0 = time.perf_counter()
    if swap_mid:
        for _ in range(3):
            srv.step()
        srv.swap_params(srv.params, tag="bench-identity")
    srv.run()
    cont_s = time.perf_counter() - t0
    srv.assert_quiescent()
    cont_toks = sum(len(s.generated) for s in srv.finished)

    seq_rate = seq_toks / max(seq_s, 1e-9)
    cont_rate = cont_toks / max(cont_s, 1e-9)
    return {
        "sessions": len(prompts),
        "max_batch": scfg.max_batch,
        "block_size": scfg.block_size,
        "num_blocks": scfg.num_blocks,
        "pad_len": pad, "gen": gen,
        "seq_tok_s": round(seq_rate, 2),
        "cont_tok_s": round(cont_rate, 2),
        "speedup": round(cont_rate / max(seq_rate, 1e-9), 3),
        "seq": _lat(seq_done), "cont": _lat(srv.finished),
        "decode_steps": srv.stats()["decode_steps"],
        "swapped": swap_mid,
        "dropped": len(prompts) - len(srv.finished),
        "tokens_match_seq": sorted(
            (s.sid, tuple(s.generated)) for s in seq_done) == sorted(
            (s.sid, tuple(s.generated)) for s in srv.finished),
    }


def main(argv=None) -> int:
    ap = std_argparser(__doc__)
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args(argv)

    if args.full:
        loads, max_batch, pad_len, gen, bs = (8, 16, 32), 16, 48, 32, 16
    elif args.smoke:
        loads, max_batch, pad_len, gen, bs = (4, 12), 8, 16, 12, 8
    else:
        loads, max_batch, pad_len, gen, bs = (4, 8, 16), 8, 24, 16, 8

    cfg = get_smoke_config(ARCH) if not args.full else get_config(ARCH)
    # f32 on CPU: keeps the greedy token streams of both arms comparable
    cfg = dataclasses.replace(cfg, dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)

    need = -(-(pad_len + gen) // bs)
    scfg = ServeConfig(max_batch=max_batch, block_size=bs,
                       num_blocks=1 + need * max_batch, pad_len=pad_len,
                       max_new=gen)

    rc, cells = 0, []
    for i, n in enumerate(loads):
        prompts = _mk_prompts(rng, n, pad_len, cfg.vocab_size)
        cell = run_cell(model, params, prompts, scfg,
                        swap_mid=(i == len(loads) - 1))
        cells.append(cell)
        emit("serving", sessions=n, seq_tok_s=cell["seq_tok_s"],
             cont_tok_s=cell["cont_tok_s"], speedup=cell["speedup"],
             cont_p50_ms=cell["cont"]["p50_ms"],
             cont_p99_ms=cell["cont"]["p99_ms"],
             seq_p50_ms=cell["seq"]["p50_ms"],
             seq_p99_ms=cell["seq"]["p99_ms"],
             dropped=cell["dropped"],
             tokens_match=cell["tokens_match_seq"])
        if cell["dropped"]:
            print(f"# FAIL {cell['dropped']} sessions dropped "
                  f"(swap={cell['swapped']}) at load {n}", flush=True)
            rc = 1
        if not cell["tokens_match_seq"]:
            print(f"# FAIL greedy token mismatch engine vs sequential "
                  f"at load {n}", flush=True)
            rc = 1

    top = cells[-1]
    if top["speedup"] < GATE_SPEEDUP:
        print(f"# FAIL continuous batching below the {GATE_SPEEDUP}x "
              f"tokens/s gate at load {top['sessions']} "
              f"(got {top['speedup']}x)", flush=True)
        rc = 1
    summary = {
        "top_load_speedup": top["speedup"],
        "gate": GATE_SPEEDUP,
        "hotswap_zero_drop": top["swapped"] and top["dropped"] == 0,
        "max_cont_tok_s": max(c["cont_tok_s"] for c in cells),
    }
    emit("serving_summary", **summary)
    with open(args.out, "w") as f:
        json.dump({"benchmark": "serving", "arch": ARCH,
                   "smoke": bool(args.smoke), "seed": args.seed,
                   "summary": summary, "cells": cells}, f, indent=2)
    print(f"# wrote {args.out}", flush=True)
    return rc


if __name__ == "__main__":
    sys.exit(main())
