"""Fig. 11 — approximate aggregation: smaller groups / fewer-or-more MAR
rounds trade exactness for communication (up to 33% cheaper at equal
utility over multiple iterations)."""
from __future__ import annotations

import sys

from benchmarks.common import emit, scale, std_argparser
from repro.core.federation import FederationConfig, run_federation


def main(argv=None) -> int:
    ap = std_argparser(__doc__)
    args = ap.parse_args(argv)
    s = scale(args.full)

    # paper setting at 125 peers: (5, 3 rounds) exact vs (3, 4 rounds)
    settings = [(5, None, "exact_5^3"), (3, 4, "approx_3x4"),
                (3, 3, "approx_3x3")] if args.full or s["peers"] == 125 \
        else [(3, None, "exact_3^3"), (3, 2, "approx_3x2"),
              (3, 1, "approx_3x1")]

    for gsize, rounds, label in settings:
        cfg = FederationConfig(
            n_peers=s["peers"], technique="mar", task="text",
            group_size=gsize, mar_rounds=rounds,
            local_batches=s["local_batches"], seed=args.seed)
        hist = run_federation(cfg, s["iters"], eval_every=s["eval_every"])
        emit("fig11_approx", setting=label, group_size=gsize,
             rounds=(rounds if rounds else "exact"),
             final_acc=round(hist["accuracy"][-1], 4),
             comm_mb=round(hist["comm_bytes"][-1] / 1e6, 1),
             disagreement=f"{hist['disagreement'][-1]:.2e}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
