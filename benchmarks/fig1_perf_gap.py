"""Fig. 1 — performance gap: communication to reach a target accuracy.

Trains every registered aggregation technique (the paper's MAR-FL /
FedAvg / RDFL / AR-FL plus the beyond-paper gossip and hierarchical
entries) on the text task and reports bytes-to-target-accuracy plus the
per-iteration byte model across peer counts (the paper's 'up to 10x
less communication than RDFL/AR-FL'). Per-source byte splits come from
the federation's CommLedger.
"""
from __future__ import annotations

import sys

from benchmarks.common import emit, scale, std_argparser
from repro.core import topology
from repro.core.federation import FederationConfig, run_federation
from repro.core.moshpit import plan_grid


def main(argv=None) -> int:
    ap = std_argparser(__doc__)
    ap.add_argument("--target", type=float, default=0.30)
    args = ap.parse_args(argv)
    s = scale(args.full)

    # analytic scaling table (exact Fig. 1 curves)
    for row in topology.complexity_table(
            model_bytes=10_000_000, peer_counts=(16, 64, 125, 512, 4096)):
        emit("fig1_scaling", **row)

    # trained comm-to-accuracy
    for tech in ("fedavg", "hierarchical", "mar", "gossip", "rdfl", "ar"):
        cfg = FederationConfig(
            n_peers=s["peers"], technique=tech, task="text",
            local_batches=s["local_batches"], seed=args.seed)
        hist = run_federation(cfg, s["iters"], eval_every=s["eval_every"])
        reached = next((c for a, c in zip(hist["accuracy"],
                                          hist["comm_bytes"])
                        if a >= args.target), None)
        emit("fig1_train", technique=tech, peers=s["peers"],
             final_acc=round(hist["accuracy"][-1], 4),
             comm_mb=round(hist["comm_bytes"][-1] / 1e6, 1),
             sim_s=round(hist["sim_s"][-1], 3),
             mb_to_target=(round(reached / 1e6, 1)
                           if reached else "not_reached"))
    return 0


if __name__ == "__main__":
    sys.exit(main())
