"""Kernel micro-benchmarks: interpret-mode correctness-scale timings of
the Pallas kernels vs their jnp references (CPU wall-times are NOT TPU
projections — roofline numbers live in the dry-run)."""
from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, std_argparser
from repro.kernels import ops, ref


def _time(fn, *args, n=3):
    fn(*args)  # warm
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def main(argv=None) -> int:
    ap = std_argparser(__doc__)
    args = ap.parse_args(argv)
    rng = np.random.default_rng(args.seed)

    b, s, h, kvh, d = 1, 256, 8, 2, 64
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, kvh, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, kvh, d)), jnp.float32)
    emit("kernel", name="flash_attention", shape=f"{b}x{s}x{h}x{d}",
         us_kernel=round(_time(lambda *a: ops.flash_attention(*a), q, k, v)),
         us_ref=round(_time(
             lambda *a: ref.flash_attention_ref(*a), q, k, v)))

    qd = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
    lens = jnp.asarray([s], jnp.int32)
    emit("kernel", name="decode_attention", shape=f"{b}x{s}x{h}x{d}",
         us_kernel=round(_time(
             lambda *a: ops.decode_attention(*a), qd, k, v, lens)),
         us_ref=round(_time(
             lambda *a: ref.decode_attention_ref(*a), qd, k, v, lens)))

    # serving-shape decode: dense cache vs paged pool (block table
    # indirection cost on identical KV bytes; bench gate lives in
    # benchmarks/serving.py)
    bsrv, bs = 8, 16
    nblk = s // bs
    qp = jnp.asarray(rng.normal(size=(bsrv, h, d)), jnp.float32)
    kd = jnp.asarray(rng.normal(size=(bsrv, s, kvh, d)), jnp.float32)
    vd = jnp.asarray(rng.normal(size=(bsrv, s, kvh, d)), jnp.float32)
    kp = kd.reshape(bsrv * nblk, bs, kvh, d)
    kp = jnp.concatenate([jnp.zeros((1,) + kp.shape[1:], kp.dtype), kp])
    vp = vd.reshape(bsrv * nblk, bs, kvh, d)
    vp = jnp.concatenate([jnp.zeros((1,) + vp.shape[1:], vp.dtype), vp])
    bt = jnp.arange(1, 1 + bsrv * nblk, dtype=jnp.int32).reshape(bsrv, nblk)
    lens_p = jnp.full((bsrv,), s - 3, jnp.int32)     # ragged tail
    emit("kernel", name="decode_attention_paged", shape=f"{bsrv}x{s}x{h}x{d}",
         block_size=bs,
         us_dense=round(_time(
             lambda *a: ops.decode_attention(*a), qp, kd, vd, lens_p)),
         us_paged=round(_time(
             lambda *a: ops.paged_decode_attention(*a), qp, kp, vp, bt,
             lens_p)),
         us_ref=round(_time(
             lambda *a: ref.paged_decode_attention_ref(*a), qp, kp, vp, bt,
             lens_p)))

    nh, dk, dv = 2, 16, 32
    qs = jnp.asarray(rng.normal(size=(b, nh, s, dk)), jnp.float32)
    ks = jnp.asarray(rng.normal(size=(b, nh, s, dk)) * 0.3, jnp.float32)
    vs = jnp.asarray(rng.normal(size=(b, nh, s, dv)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.01, 0.5, size=(b, nh, s)), jnp.float32)
    h0 = jnp.zeros((b, nh, dk, dv), jnp.float32)
    emit("kernel", name="ssd_scan", shape=f"{b}x{nh}x{s}x{dk}x{dv}",
         us_kernel=round(_time(lambda *x: ops.ssd_scan(*x),
                               qs, ks, vs, a, h0)),
         us_ref=round(_time(lambda *x: ref.ssd_scan_ref(*x),
                            qs, ks, vs, a, h0)))

    g, m, dd = 8, 5, 4096
    x = jnp.asarray(rng.normal(size=(g, m, dd)), jnp.float32)
    mask = jnp.asarray(rng.random((g, m)) < 0.8, jnp.float32)
    emit("kernel", name="group_mean", shape=f"{g}x{m}x{dd}",
         us_kernel=round(_time(lambda *x: ops.group_mean(*x), x, mask)),
         us_ref=round(_time(lambda *x: ref.group_mean_ref(*x), x, mask)))
    return 0


if __name__ == "__main__":
    sys.exit(main())
